"""Telemetry substrate (repro.obs): in-dispatch metric taps, the structured
run tracer, and the unified metrics pipeline.

The load-bearing claims pinned here:

* taps are FREE when off — a run without a tracer is bit-identical to one
  with taps enabled (same trajectory, same wire bits), because the tap
  vector rides the SAME fused dispatch and taps-off keeps the pre-telemetry
  jit signatures;
* the event stream is ENGINE-INVARIANT — the sequential engine and the
  cohort engine at cohort_size=1 emit identical typed events (modulo wall
  clock and warm-cache-dependent compile events) on the same seed;
* taps are SHARDING-INVARIANT — the segment-sharded flush produces the
  bitwise-identical tap vector to the single-device dispatch (gather to
  replicated + slice to the true n before the shared tap reduction); one
  subprocess test re-runs the comparison under 8 forced virtual devices;
* taps-on is still ONE dispatch per flush / per cohort tier-group
  (trace_guard over the fused-entry counters);
* every emitted stream passes the JSONL schema validator, and the old
  metrics keys survive the pipeline unification bit-for-bit.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QAFeL, QAFeLConfig
from repro.core.staleness import StalenessMonitor
from repro.obs import (COHORT_TAP_NAMES, FLUSH_TAP_NAMES, AccuracyPoint,
                       CompileWatch, Event, RunTracer, summary_table,
                       validate_events, validate_jsonl, write_jsonl)
from repro.obs.report import report_rows
from repro.obs.schema import _selftest
from repro.sim import AsyncFLSimulator, CohortAsyncFLSimulator, SimConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

PARAMS0 = {"w": jnp.zeros((300,), jnp.float32),
           "b": jnp.ones((7,), jnp.float32)}
D = 300


def quad_loss(params, batch, key):
    del key
    return jnp.sum((params["w"] - batch["target"]) ** 2)


def make_qcfg(**kw):
    base = dict(client_lr=0.1, server_lr=1.2, server_momentum=0.3,
                buffer_size=3, local_steps=2, client_quantizer="qsgd4",
                server_quantizer="qsgd4")
    base.update(kw)
    return QAFeLConfig(**base)


def client_batches(cid, key):
    del cid  # key-derived so both engines see identical data in RNG order
    return {"target": jnp.broadcast_to(
        jax.random.normal(key, (D,)) + 3.0, (2, D))}


def eval_fn(params):
    # host f64 reduction: a device-side jnp.mean over a SHARDED x would
    # group the f32 sum differently per device count, and the eval event's
    # accuracy would spuriously break stream bit-invariance
    return float(np.asarray(params["w"], dtype=np.float64).mean())


def run_sim(engine="sequential", taps=True, mesh=None, seed=0,
            max_uploads=12, chunk_rows=None, **qkw):
    tracer = RunTracer(taps=True) if taps else None
    algo = QAFeL(make_qcfg(**qkw), quad_loss, PARAMS0, mesh=mesh,
                 telemetry=tracer, chunk_rows=chunk_rows)
    scfg = SimConfig(concurrency=4, max_uploads=max_uploads,
                     eval_every_steps=1, seed=seed, track_hidden_replicas=1)
    if engine == "sequential":
        sim = AsyncFLSimulator(algo, scfg, client_batches, eval_fn)
    else:
        sim = CohortAsyncFLSimulator(algo, scfg, client_batches, eval_fn,
                                     scenario="identity", cohort_size=1)
    return sim.run(), tracer


@pytest.fixture(scope="module")
def traced_run():
    return run_sim(taps=True)


# -- records and registries -------------------------------------------------


def test_accuracy_point_is_a_tuple():
    """The named record type must stay drop-in for the positional tuples it
    replaced: equality, unpacking, and indexing all behave identically."""
    p = AccuracyPoint(1.5, 12, 4, 0.75)
    assert p == (1.5, 12, 4, 0.75)
    assert isinstance(p, tuple)
    t_sim, uploads, step, acc = p
    assert (p[0], p[1], p[2], p[3]) == (t_sim, uploads, step, acc)
    assert p.accuracy == 0.75
    assert p.as_dict() == {"t_sim": 1.5, "uploads": 12, "step": 4,
                           "accuracy": 0.75}


def test_staleness_histogram():
    mon = StalenessMonitor()
    for tau in (0, 0, 1, 2, 3, 4, 8, 100):
        mon.observe(tau)
    mon.record_dropped(7)
    h = mon.histogram(bins=4)
    assert h["edges"] == (0, 1, 2, 4)
    # buckets: [0,1) [1,2) [2,4) [4,inf)
    assert h["accepted"] == (2, 1, 2, 3)
    assert h["dropped"] == (0, 0, 0, 1)
    with pytest.raises(ValueError):
        mon.histogram(bins=1)
    # the histogram is part of the one metrics surface
    assert mon.summary()["tau_hist"] == mon.histogram()


def test_tracer_ring_eviction():
    t = RunTracer(capacity=4)
    for i in range(6):
        t.emit("flush", step=i, window=3)
    assert len(t.events()) == 4
    assert t.dropped_events == 2
    assert t.counters()["events_evicted"] == 2
    assert [e.step for e in t.events()] == [2, 3, 4, 5]


def test_event_comparable_drops_wall_clock():
    t = RunTracer()
    t.emit("eval", step=1, accuracy=0.5)
    (e,) = t.events()
    assert isinstance(e, Event)
    assert "t_wall" in e.as_dict()
    assert "t_wall" not in e.comparable()


def test_tracer_rejects_unknown_kind():
    with pytest.raises(ValueError):
        RunTracer().emit("not_a_kind")


# -- schema -----------------------------------------------------------------


def test_schema_selftest():
    _selftest()


def test_schema_rejects_malformed_streams():
    t = RunTracer()
    t.set_sim_time(1.0)
    t.emit("flush", step=1, window=3)
    rows = [e.as_dict() for e in t.events()]
    assert validate_events(rows) == []
    assert validate_events([]) != []  # empty trace is an error
    bad_seq = [dict(rows[0]), dict(rows[0])]  # duplicated seq
    assert validate_events(bad_seq) != []
    missing = dict(rows[0])
    del missing["window"]
    assert validate_events([missing]) != []
    unknown = dict(rows[0], kind="telemetry")
    assert validate_events([unknown]) != []


def test_run_trace_jsonl_roundtrip(traced_run, tmp_path):
    """A real run's stream serializes to schema-valid JSONL whose rows
    mirror the in-memory events exactly."""
    _, tracer = traced_run
    path = tmp_path / "trace.jsonl"
    write_jsonl(tracer, str(path))
    assert validate_jsonl(str(path)) == []
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert rows == [e.as_dict() for e in tracer.events()]
    kinds = {r["kind"] for r in rows}
    assert {"upload", "flush", "broadcast", "eval"} <= kinds


# -- taps: zero-cost when off, correct when on ------------------------------


def test_taps_off_run_is_bit_identical(traced_run):
    """Attaching a taps-enabled tracer must not change a single bit of the
    trajectory: same accuracy trace, same traffic/staleness metrics, same
    final hidden state."""
    res_on, tracer = traced_run
    res_off, _ = run_sim(taps=False)
    assert res_off.accuracy_trace == res_on.accuracy_trace
    m_on = {k: v for k, v in res_on.metrics.items()
            if not (k.startswith("flush/") or k.startswith("upload/")
                    or k.startswith("events_") or k.startswith("traces_"))}
    assert m_on == res_off.metrics
    # the tap series themselves: one point per flush / per accepted upload
    n_flush = len(tracer.events("flush"))
    for name in FLUSH_TAP_NAMES:
        assert len(res_on.metrics[f"flush/{name}"]) == n_flush
    n_up = len(tracer.events("upload"))
    for name in COHORT_TAP_NAMES:
        assert len(res_on.metrics[f"upload/{name}"]) == n_up


def test_flush_tap_values_identity_server():
    """With an identity SERVER quantizer the broadcast quantization error is
    exactly 0.0 and the norm taps are positive and finite; qsgd clients keep
    the packed buffer window, so the staleness-weight taps are live."""
    res, tracer = run_sim(server_quantizer="identity")
    qerr = res.metrics["flush/bcast_qerr_rel"]
    assert qerr and all(v == 0.0 for v in qerr)
    for name in ("delta_norm", "update_norm", "bcast_diff_norm"):
        series = res.metrics[f"flush/{name}"]
        assert all(np.isfinite(v) and v > 0.0 for v in series)
    # buffer-size weights: sum of K staleness weights, each in (0, 1]
    k = make_qcfg().buffer_size
    for s, lo in zip(res.metrics["flush/weight_sum"],
                     res.metrics["flush/weight_min"]):
        assert 0.0 < lo <= 1.0 and lo <= s <= k


def test_upload_tap_qerr_zero_identity_client():
    """Identity CLIENT quantizer -> every upload's relative quantization
    error tap is exactly 0.0 (the uploads bypass the packed stack, so the
    flush weight taps report the documented zeros there)."""
    res, _ = run_sim(client_quantizer="identity")
    up_qerr = res.metrics["upload/upload_qerr_rel"]
    assert up_qerr and all(v == 0.0 for v in up_qerr)
    assert all(v == 0.0 for v in res.metrics["flush/weight_sum"])


def test_qsgd_tap_qerr_in_unit_range(traced_run):
    res, _ = traced_run
    for series in (res.metrics["flush/bcast_qerr_rel"],
                   res.metrics["upload/upload_qerr_rel"]):
        assert series and all(0.0 < v < 1.0 for v in series)


# -- engine and sharding invariance -----------------------------------------


def _comparable_stream(tracer):
    # compile events are warm-cache-dependent (a second same-process run
    # retraces nothing) so they never enter stream comparisons
    return [e.comparable() for e in tracer.events() if e.kind != "compile"]


def test_event_stream_engine_invariant(traced_run):
    """Sequential engine vs cohort engine at cohort_size=1: identical typed
    event stream and identical metrics on the same seed."""
    res_a, tr_a = traced_run
    res_b, tr_b = run_sim(engine="cohort")
    assert _comparable_stream(tr_a) == _comparable_stream(tr_b)
    m_b = dict(res_b.metrics)
    assert m_b.pop("dropped_uploads") == 0
    assert m_b == res_a.metrics
    assert res_b.accuracy_trace == res_a.accuracy_trace


def test_flush_taps_sharding_invariant(traced_run):
    """The segment-sharded flush's tap vector must be BITWISE equal to the
    single-device one (1 segment here; genuinely 8-way under the 8-device
    CI job, where the mesh spans all visible devices)."""
    from repro.launch.mesh import make_sim_mesh
    res_a, tr_a = traced_run
    res_b, tr_b = run_sim(mesh=make_sim_mesh())
    for name in FLUSH_TAP_NAMES:
        key = f"flush/{name}"
        assert res_b.metrics[key] == res_a.metrics[key], key
    assert _comparable_stream(tr_a) == _comparable_stream(tr_b)


def test_flush_taps_mesh2d_chunked_invariant(traced_run):
    """The 2-D ("data","model") mesh with the chunked flush encode must
    produce the same tap series bit for bit: the model-axis tap reduction
    gathers to replicated before reducing along the d-chunks, and the
    chunked encode's counter-hash dither is keyed by global element index,
    so neither sharding nor chunking may show up in the taps."""
    from repro.launch.mesh import make_sim_mesh2d
    res_a, tr_a = traced_run
    res_b, tr_b = run_sim(mesh=make_sim_mesh2d((1, 1)), chunk_rows=1)
    for name in FLUSH_TAP_NAMES:
        key = f"flush/{name}"
        assert res_b.metrics[key] == res_a.metrics[key], key
    assert _comparable_stream(tr_a) == _comparable_stream(tr_b)


def test_eight_virtual_devices_taps_invariant():
    """Force 8 host devices in a subprocess and assert the sharded flush
    tap series and event stream match the single-device run bit for bit."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import tests.test_obs as T
        from repro.launch.mesh import make_sim_mesh, make_sim_mesh2d
        res_a, tr_a = T.run_sim()
        res_b, tr_b = T.run_sim(mesh=make_sim_mesh(8))
        res_c, tr_c = T.run_sim(mesh=make_sim_mesh2d((2, 4)), chunk_rows=1)
        for name in T.FLUSH_TAP_NAMES:
            key = "flush/" + name
            assert res_b.metrics[key] == res_a.metrics[key], key
            assert res_c.metrics[key] == res_a.metrics[key], "2d:" + key
        assert T._comparable_stream(tr_b) == T._comparable_stream(tr_a)
        assert T._comparable_stream(tr_c) == T._comparable_stream(tr_a)
        assert T.validate_events(
            [e.as_dict() for e in tr_b.events()]) == []
        print("OBS_8DEV_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=560,
        env={**os.environ,
             "PYTHONPATH": os.path.join(REPO, "src") + os.pathsep + REPO},
        cwd=REPO)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "OBS_8DEV_OK" in out.stdout


# -- single dispatch with taps on -------------------------------------------


def test_taps_on_is_still_one_dispatch():
    """Taps ride the existing fused dispatches: one server_flush call per
    flush, one cohort_step call per client, zero base-kernel calls inside
    either guarded window."""
    from repro.analysis_static.trace_guard import trace_guard
    tracer = RunTracer(taps=True)
    algo = QAFeL(make_qcfg(), quad_loss, PARAMS0, telemetry=tracer)
    key = jax.random.PRNGKey(0)
    flushes = 0
    with trace_guard("server_flush", retraces=None) as gs, \
            trace_guard("cohort_step", retraces=None) as gc:
        while flushes < 2:
            key, k1, k2, k3 = jax.random.split(key, 4)
            with gc.exclusive():
                msg, _ = algo.run_client(client_batches(0, k1), k2)
            with gs.exclusive():
                bmsg = algo.receive(msg, k3)
            if bmsg is not None:
                flushes += 1
    assert gs.calls == 2 and gs.other_calls == 0
    assert gc.calls >= 2 * make_qcfg().buffer_size and gc.other_calls == 0


# -- compile tracking and reporting -----------------------------------------


def test_compile_watch_and_events(traced_run):
    _, tracer = traced_run
    compiles = tracer.events("compile")
    assert compiles, "a cold run must record its fused-entry traces"
    entries = {e.data["entry"] for e in compiles}
    assert "server_flush" in entries
    assert all(e.data["retraces"] >= 1 for e in compiles)
    # counters carry the totals; metrics() deliberately excludes them
    assert tracer.counters()["traces_server_flush"] >= 1
    assert not any(k.startswith("traces_") for k in tracer.metrics())
    # a fresh watch sees the already-warm cache: zero deltas
    w = CompileWatch()
    assert all(v == 0 for v in w.poll().values())


def test_report_rows_and_summary_table(traced_run):
    _, tracer = traced_run
    rows = []
    report_rows(tracer, lambda name, us, derived="": rows.append(
        (name, us, derived)))
    names = [r[0] for r in rows]
    assert "obs/events" in names
    assert any(n.startswith("obs/flush/") for n in names)
    # obs rows must never enter the --check speedup gate
    assert all("speedup" not in n for n in names)
    table = summary_table(tracer)
    assert "events_flush" in table and "flush/bcast_qerr_rel" in table


def test_metrics_surface_keeps_legacy_keys(traced_run):
    """The unified metrics() pipeline preserves the pre-PR key set (traffic
    meter, staleness monitor, server step counter) alongside the new
    series."""
    res, _ = traced_run
    for key in ("upload_MB", "broadcast_MB", "kB_per_upload", "tau_max",
                "tau_mean", "tau_hist", "server_steps", "hidden_drift",
                "replicas_in_sync"):
        assert key in res.metrics, key
