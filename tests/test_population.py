"""Device-resident population engine: equivalence pins against the cohort
engine, draw-law invariance, state telemetry, staleness batching, and the
lifecycle substrate at scale-model sizes."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QAFeL, QAFeLConfig
from repro.core.staleness import StalenessMonitor
from repro.data import FederatedPartition, SyntheticCelebA
from repro.models.cnn import cnn_accuracy, cnn_loss, init_cnn
from repro.obs.events import RunTracer
from repro.obs.schema import validate_events, validate_jsonl
from repro.obs.taps import POPULATION_STATE_NAMES
from repro.sim import (CohortAsyncFLSimulator, PopulationAsyncFLSimulator,
                       PopulationEngine, SCENARIOS, ScenarioConfig, SimConfig)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def task():
    ds = SyntheticCelebA(n_samples=400)
    part = FederatedPartition(labels=ds.labels, n_clients=40)
    params0 = init_cnn(jax.random.PRNGKey(0))

    def loss_fn(params, batch, key):
        return cnn_loss(params, batch, train=True, key=key)[0]

    def client_batches(cid, key):
        rng = np.random.default_rng(int(cid) * 1009 + 7)
        b = [part.client_batch(ds, int(cid), 8, rng) for _ in range(2)]
        return {k: jnp.stack([jnp.asarray(bi[k]) for bi in b]) for k in b[0]}

    test_idx = part.split_indices(part.val_clients)[:128]
    test_batch = {k: jnp.asarray(v) for k, v in ds.batch(test_idx).items()}
    eval_fn = jax.jit(lambda p: cnn_accuracy(p, test_batch))
    return loss_fn, params0, client_batches, eval_fn


def run_engine(task, engine, scenario="identity", cohort_size=4,
               max_uploads=16, seed=0, tracer=None, **kw):
    loss_fn, params0, client_batches, eval_fn = task
    qcfg = QAFeLConfig(client_lr=0.05, server_lr=1.0, server_momentum=0.3,
                       buffer_size=4, local_steps=2,
                       client_quantizer="qsgd4", server_quantizer="qsgd4")
    algo = QAFeL(qcfg, loss_fn, params0, telemetry=tracer)
    scfg = SimConfig(concurrency=8, max_uploads=max_uploads,
                     eval_every_steps=2, seed=seed, track_hidden_replicas=1)
    if engine == "cohort":
        sim = CohortAsyncFLSimulator(algo, scfg, client_batches, eval_fn,
                                     scenario=scenario,
                                     cohort_size=cohort_size)
    else:
        sim = PopulationAsyncFLSimulator(algo, scfg, client_batches, eval_fn,
                                         scenario=scenario,
                                         cohort_size=cohort_size, **kw)
    return sim.run()


def _strip_population(metrics):
    return {k: v for k, v in metrics.items() if k != "population_states"
            and not k.startswith("population/")}


def _comparable_events(tracer):
    """Event stream for cross-engine comparison: drop wall clock, compare
    sim times to f32 tolerance separately, strip the population field only
    the population engine carries."""
    seq = []
    times = []
    for e in tracer.events():
        if e.kind == "compile":  # warm-cache dependent
            continue
        d = e.comparable()
        d.pop("population", None)
        times.append(d.pop("t_sim"))
        seq.append(d)
    return seq, times


def _assert_equivalent(rc, rp, tc=None, tp=None):
    """The pin: identical event/accuracy SEQUENCE and model state bit for
    bit; event TIMES agree to f32 (device timeline) vs float64 (host)
    rounding."""
    assert rp.server_steps == rc.server_steps
    assert rp.uploads == rc.uploads
    assert rp.final_accuracy == rc.final_accuracy
    assert [(p[1], p[2], p[3]) for p in rp.accuracy_trace] == \
        [(p[1], p[2], p[3]) for p in rc.accuracy_trace]
    np.testing.assert_allclose([p[0] for p in rp.accuracy_trace],
                               [p[0] for p in rc.accuracy_trace], rtol=1e-5)
    np.testing.assert_allclose(rp.sim_time, rc.sim_time, rtol=1e-5)
    assert _strip_population(rp.metrics) == dict(rc.metrics)
    if tc is not None:
        seq_c, times_c = _comparable_events(tc)
        seq_p, times_p = _comparable_events(tp)
        assert seq_p == seq_c  # uploads/drops/flushes/broadcasts/evals
        np.testing.assert_allclose(times_p, times_c, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Equivalence pins (the acceptance anchors)
# ---------------------------------------------------------------------------


def test_host_draws_identity_matches_cohort(task):
    """With host-fed sampler draws the population engine consumes the exact
    numpy/jax streams of the cohort engine and must reproduce its whole
    trajectory: upload sequence, fan-out counts, staleness stats, byte
    meters, accuracies — bit for bit (times to f32)."""
    tc, tp = RunTracer(taps=False), RunTracer(taps=False)
    rc = run_engine(task, "cohort", tracer=tc)
    rp = run_engine(task, "population", tracer=tp, draws="host")
    _assert_equivalent(rc, rp, tc, tp)
    assert rc.metrics["replicas_in_sync"] and rp.metrics["replicas_in_sync"]


def test_host_draws_dropout_scenario_matches_cohort(task):
    """Host mode pins every scenario feature, not just the identity path:
    lognormal latencies, Poisson arrivals, dropout reaping."""
    tc, tp = RunTracer(taps=False), RunTracer(taps=False)
    rc = run_engine(task, "cohort", scenario="lognormal_dropout", tracer=tc)
    rp = run_engine(task, "population", scenario="lognormal_dropout",
                    tracer=tp, draws="host")
    _assert_equivalent(rc, rp, tc, tp)
    assert rp.metrics["dropped_uploads"] == rc.metrics["dropped_uploads"] > 0


def test_device_draws_trace_scenario_matches_cohort(task):
    """The deterministic trace scenario draws identically under the
    in-kernel counter-hash law and the host sampler (both cycle the trace
    by global client id), so DEVICE mode must match the cohort engine."""
    rc = run_engine(task, "cohort", scenario="trace_replay")
    rp = run_engine(task, "population", scenario="trace_replay",
                    draws="device")
    _assert_equivalent(rc, rp)


def test_device_draws_deterministic_and_seed_sensitive(task):
    r1 = run_engine(task, "population", scenario="lognormal_dropout",
                    max_uploads=12, seed=3)
    r2 = run_engine(task, "population", scenario="lognormal_dropout",
                    max_uploads=12, seed=3)
    r3 = run_engine(task, "population", scenario="lognormal_dropout",
                    max_uploads=12, seed=4)
    assert r1.accuracy_trace == r2.accuracy_trace
    assert dict(r1.metrics) == dict(r2.metrics)
    assert r1.sim_time != r3.sim_time


def test_deliver_batching_is_trajectory_invariant(task):
    """Draining D completions per dispatch is pure batching: deliveries
    admitted between macro steps never reorder (all batched deadlines are
    strictly earlier than the next arrival), so the trajectory is
    independent of deliver_batch."""
    r1 = run_engine(task, "population", draws="host", deliver_batch=1)
    r8 = run_engine(task, "population", draws="host", deliver_batch=8)
    assert r1.accuracy_trace == r8.accuracy_trace
    assert _strip_population(r1.metrics) == _strip_population(r8.metrics)


# ---------------------------------------------------------------------------
# Population telemetry
# ---------------------------------------------------------------------------


def test_population_state_telemetry(task, tmp_path):
    tracer = RunTracer(taps=False)
    res = run_engine(task, "population", scenario="lognormal_dropout",
                     tracer=tracer)
    states = res.metrics["population_states"]
    assert set(states) == set(POPULATION_STATE_NAMES)
    assert all(isinstance(v, int) and v >= 0 for v in states.values())
    assert sum(states.values()) > 0  # capacity is conserved across states
    # eval events carry the same per-state counts...
    evs = [e for e in tracer.events("eval")]
    assert evs and all("population" in e.data for e in evs)
    # ...surface as metrics() series...
    m = tracer.metrics()
    for name in POPULATION_STATE_NAMES:
        assert len(m[f"population/{name}"]) == len(evs)
    # ...and the exported JSONL schema-validates with the new field
    path = tmp_path / "pop_trace.jsonl"
    tracer.to_jsonl(path)
    assert validate_jsonl(path) == []


def test_schema_rejects_bad_population_field():
    row = {"kind": "eval", "seq": 0, "step": 0, "t_sim": 0.0, "t_wall": 0.0,
           "accuracy": 0.5}
    assert validate_events([dict(row, population={"idle": 1, "working": 0,
                                                  "offline": 0,
                                                  "dropped": 2})]) == []
    assert validate_events([dict(row, population={"bogus": 1})])
    assert validate_events([dict(row, population={"idle": -1})])
    assert validate_events([dict(row, population={"idle": 1.5})])
    up = {"kind": "upload", "seq": 0, "step": 0, "t_sim": 0.0, "t_wall": 0.0,
          "client": 0, "tau": 0, "population": {"idle": 1}}
    assert validate_events([up])  # only eval events carry population


# ---------------------------------------------------------------------------
# Draw law: counter-hash keyed by global client id
# ---------------------------------------------------------------------------


def test_scenario_draws_are_batch_invariant():
    """A client's draws depend only on (seed, cid): splitting the id range
    across admission batches of any size yields identical values — the
    concurrency/tiling-invariance contract of the in-kernel law."""
    from repro.kernels.population import run_seeds, scenario_draws
    from repro.sim.population import compile_scenario
    cfg = ScenarioConfig(latency="lognormal", arrival="poisson", dropout=0.2,
                         straggler_frac=0.3, straggler_mult=2.0,
                         tiers=((0.3, "qsgd2"),))
    scn = compile_scenario(cfg, 64)
    seeds = run_seeds(7)
    cids = jnp.arange(96, dtype=jnp.int32)
    full = scenario_draws(scn, seeds, cids)
    for chunk in (1, 7, 32):
        parts = [scenario_draws(scn, seeds, cids[i:i + chunk])
                 for i in range(0, 96, chunk)]
        for k in range(4):
            got = np.concatenate([np.asarray(p[k]) for p in parts])
            np.testing.assert_array_equal(got, np.asarray(full[k]), err_msg=f"component {k} chunk {chunk}")


def test_scenario_draw_distributions():
    """Sanity of the inverse-CDF transforms at scale: means within a few
    percent of the scenario's analytic values."""
    from repro.kernels.population import run_seeds, scenario_draws
    from repro.sim.population import compile_scenario
    cids = jnp.arange(200_000, dtype=jnp.int32)
    seeds = run_seeds(11)
    for cfg in (ScenarioConfig(),
                ScenarioConfig(latency="lognormal", lognormal_sigma=1.0),
                ScenarioConfig(latency="uniform"),
                ScenarioConfig(straggler_frac=0.2, straggler_mult=4.0)):
        scn = compile_scenario(cfg, 100)
        inter, dur, drops, tiers = scenario_draws(scn, seeds, cids)
        assert np.isfinite(np.asarray(dur)).all()
        np.testing.assert_allclose(float(jnp.mean(dur)),
                                   cfg.effective_mean_duration, rtol=0.05)
        np.testing.assert_allclose(float(jnp.mean(inter)), 1.0 / scn.rate,
                                   rtol=0.05)
    dcfg = ScenarioConfig(dropout=0.25)
    _, _, drops, _ = scenario_draws(compile_scenario(dcfg, 100), seeds, cids)
    np.testing.assert_allclose(float(jnp.mean(drops)), 0.25, atol=0.01)


# ---------------------------------------------------------------------------
# StalenessMonitor.observe_batch
# ---------------------------------------------------------------------------


def test_observe_batch_bit_equal_to_repeated_observe():
    taus = np.array([0, 3, 1, 0, 7, 2], dtype=np.int32)
    m1, m2 = StalenessMonitor(), StalenessMonitor()
    m1.observe_batch(taus)
    for t in taus:
        m2.observe(int(t))
    assert m1.history == m2.history
    assert m1.summary() == m2.summary()


def test_observe_batch_error_behavior_matches_sequential():
    # negative tau: prefix recorded, same exception as observe
    m1, m2 = StalenessMonitor(), StalenessMonitor()
    with pytest.raises(ValueError, match="negative staleness -2"):
        m1.observe_batch([1, 0, -2, 5])
    with pytest.raises(ValueError, match="negative staleness -2"):
        for t in [1, 0, -2, 5]:
            m2.observe(t)
    assert m1.history == m2.history == [1, 0]
    # bound violation under max_allowed
    m3, m4 = StalenessMonitor(max_allowed=3), StalenessMonitor(max_allowed=3)
    with pytest.raises(RuntimeError, match="exceeds tau_max=3"):
        m3.observe_batch([2, 3, 4])
    with pytest.raises(RuntimeError, match="exceeds tau_max=3"):
        for t in [2, 3, 4]:
            m4.observe(t)
    assert m3.history == m4.history == [2, 3]


# ---------------------------------------------------------------------------
# Lifecycle substrate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_lifecycle_engine_runs_every_preset(name):
    eng = PopulationEngine(name, concurrency=32, horizon=4.0, seed=1,
                           admit_batch=4, deliver_batch=4)
    m = eng.advance_to(4.0)
    states = m["population_states"]
    assert sum(states.values()) == eng.capacity  # conservation
    assert m["admitted"] == (states["working"] + states["offline"]
                             + m["delivered"] + m["discarded"])
    assert m["delivered"] > 0
    assert m["staleness"]["n"] == m["delivered"]
    if SCENARIOS[name].dropout > 0:
        assert m["dropped"] == states["offline"] + m["discarded"]
    else:
        assert m["dropped"] == 0


def test_lifecycle_engine_deterministic():
    m1 = PopulationEngine("lognormal_dropout", concurrency=64, horizon=3.0,
                          seed=5, admit_batch=8).advance_to(3.0)
    m2 = PopulationEngine("lognormal_dropout", concurrency=64, horizon=3.0,
                          seed=5, admit_batch=8).advance_to(3.0)
    assert m1 == m2
    m3 = PopulationEngine("lognormal_dropout", concurrency=64, horizon=3.0,
                          seed=6, admit_batch=8).advance_to(3.0)
    assert m3 != m1


def test_lifecycle_concurrency_calibration():
    """Little's law end to end: the in-flight population fluctuates around
    the requested concurrency once warmed up."""
    eng = PopulationEngine("identity", concurrency=256, horizon=8.0, seed=2,
                           admit_batch=32)
    m = eng.advance_to(8.0)
    in_flight = (m["population_states"]["working"]
                 + m["population_states"]["offline"])
    assert 0.6 * 256 < in_flight < 1.6 * 256


def test_capacity_exhaustion_raises():
    with pytest.raises(RuntimeError, match="capacity exhausted"):
        PopulationEngine("identity", concurrency=64, horizon=4.0,
                         admit_batch=8, capacity=16).advance_to(4.0)


# ---------------------------------------------------------------------------
# Mesh composition (8 virtual devices, subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_population_engine_composes_with_mesh():
    """The population timeline is mesh-independent: a data-sharded QAFeL
    under the population engine reproduces the meshless run bit for bit
    (same contract the cohort engine holds)."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from repro.core import QAFeL, QAFeLConfig
        from repro.launch.mesh import make_sim_mesh
        from repro.sim import PopulationAsyncFLSimulator, SimConfig

        # elementwise loss: its gradient has no cross-element reductions, so
        # the sharded sum order cannot perturb it (same contract the cohort
        # engine's mesh bit-identity test pins)
        def loss_fn(params, batch, key):
            del key
            return jnp.sum((params["w"] - batch["target"]) ** 2)

        def client_batches(cid, key):
            # leading axis = local_steps (the client scan's step dimension)
            return {"target": jax.random.normal(key, (1, 256)) + 1.0}

        def eval_fn(params):
            return float(-jnp.mean((params["w"] - 1.0) ** 2))

        def run(mesh):
            qcfg = QAFeLConfig(client_lr=0.05, server_lr=1.0, buffer_size=4,
                               local_steps=1, client_quantizer="qsgd4",
                               server_quantizer="qsgd4")
            # fresh params per run: the server state donates its buffers
            algo = QAFeL(qcfg, loss_fn, {"w": jnp.zeros((256,), jnp.float32)},
                         mesh=mesh)
            scfg = SimConfig(concurrency=8, max_uploads=16,
                             eval_every_steps=2, seed=0,
                             track_hidden_replicas=1)
            sim = PopulationAsyncFLSimulator(
                algo, scfg, client_batches, eval_fn,
                scenario="lognormal_dropout", cohort_size=4)
            return sim.run()

        r0 = run(None)
        r8 = run(make_sim_mesh(8))
        assert r8.accuracy_trace == r0.accuracy_trace
        assert r8.final_accuracy == r0.final_accuracy
        assert r8.metrics["replicas_in_sync"]
        assert r8.metrics["population_states"] == \\
            r0.metrics["population_states"]
        print("POP_MESH_OK")
    """
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)], capture_output=True,
        text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}, cwd=REPO)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-4000:]
    assert "POP_MESH_OK" in out.stdout
